"""Online retraining: the policy learns ON DEVICE while the fused decide
scan serves, with versioned hot-swaps and crash-recovery checkpoints.

Two ways to retrain a running Percepta deployment:

  * EXPORT path (PR 4 era, still available): ``system.export_replay()``
    hands the ring to the host — full (E, C) transfer, numpy/optimizer
    step outside the system, rebuild to redeploy. Right when retraining
    is OFFLINE (nightly jobs, big models, cross-deployment aggregation)
    and the serving process must not spend device time on learning.

  * DEVICE path (this example, ``train="online"``): ``OnlineTrainer``
    jits ``replay.sample_device`` + one AdamW step into a single
    dispatch that it enqueues right BEHIND each fused decide dispatch —
    the update executes in the dispatch bubble while the host consumes,
    touches only ``batch`` sampled rows instead of exporting the ring,
    and hot-swaps the new weights into the decide carry at the next
    batch boundary (never mid-scan). Every decision row is stamped with
    the ``policy_version`` that produced it, so logs and replay stay
    attributable across swaps. Right when adaptation must be continuous
    and the model is small enough that one update fits the bubble
    (``make bench-pr7``: the device step is several times cheaper than
    one export round-trip, and serving throughput stays within ~10%).

Run: PYTHONPATH=src python examples/train_retrain.py [--windows 30]
"""
import argparse
import shutil

import numpy as np

from repro.core import PipelineConfig
from repro.core.reward import energy_reward_spec
from repro.runtime.predictor import ActionSpace, Predictor, linear_policy
from repro.runtime.receivers import SimulatedDevice
from repro.runtime.system import PerceptaSystem, SourceSpec

ap = argparse.ArgumentParser()
ap.add_argument("--windows", type=int, default=30)
ap.add_argument("--scan-k", type=int, default=5)
args = ap.parse_args()
# the pre-crash half must cover >= 2 batches so at least one train step is
# APPLIED (and hence checkpointed) before the simulated crash
assert args.windows >= 4 * args.scan_k, "--windows must be >= 4 * --scan-k"

CKDIR = "/tmp/percepta_online_ckpt"
shutil.rmtree(CKDIR, ignore_errors=True)


def build(train=None, train_cfg=None):
    srcs = [SourceSpec("meter", "mqtt",
                       SimulatedDevice("grid_kw", 60.0, base=3.0, seed=1)),
            SourceSpec("price", "http",
                       SimulatedDevice("price_eur", 300.0, base=0.2,
                                       amplitude=0.05, seed=2))]
    cfg = PipelineConfig(n_envs=2, n_streams=2, n_ticks=8, tick_s=60.0,
                         max_samples=32)
    pred = Predictor(linear_policy(2, 2),
                     energy_reward_spec(price_idx=1, grid_idx=0, temp_idx=0),
                     ActionSpace(np.array([-1., -1.]), np.array([1., 1.])),
                     2, cfg.n_features, replay_capacity=64)
    return PerceptaSystem(["bldg-0", "bldg-1"], srcs, cfg, pred,
                          speedup=5000.0, manual_time=True,
                          mode="scan_fused_decide", scan_k=args.scan_k,
                          train=train, train_cfg=train_cfg)


tcfg = {"batch_size": 64, "checkpoint_dir": CKDIR, "checkpoint_every": 1}

print(f"=== serving {args.windows} windows (K={args.scan_k}) with online "
      "retraining overlapped on the decide dispatches ===")
sys1 = build(train="online", train_cfg=tcfg)
half = (args.windows // 2 // args.scan_k) * args.scan_k
sys1.run_windows(half)
st = sys1.train_stats()
print(f"after {half} windows: dispatched {st['dispatched']} train steps, "
      f"applied {st['applied']}, policy_version {sys1.policy_version()}, "
      f"loss {st['last_loss']:.4f}")
w_crash = np.asarray(sys1.snapshot_policy()["w"]).copy()
v_crash = sys1.policy_version()
sys1.stop()
print(f"-- simulated crash at version {v_crash} --")

# restart: a fresh process restores the newest policy+optimizer snapshot,
# keeps serving, and version numbering continues where it left off
sys2 = build(train="online", train_cfg=tcfg)
restored = sys2.restore_training()
assert restored is not None, "no checkpoint found"
step, params, extra = restored
print(f"-- restored applied-step {step}, policy_version "
      f"{extra['policy_version']} --")
assert sys2.policy_version() == v_crash
assert (np.asarray(sys2.snapshot_policy()["w"]) == w_crash).all()

sys2.run_windows(args.windows - half)
st2 = sys2.train_stats()
print(f"after restart: applied {st2['applied']} total, policy_version "
      f"{sys2.policy_version()}, loss {st2['last_loss']:.4f}")
assert sys2.policy_version() > v_crash, "training must continue after resume"

# attribution: the replay ring records which policy produced every action
exp = sys2.export_replay("demo")
versions = np.asarray(exp["version"])[0]
print("replay version column (env 0):", versions)
assert (np.diff(versions) >= 0).all(), "versions must be monotone in time"
sys2.stop()
print("OK: online retraining overlaps serving, survives a crash, and every "
      "logged action is version-attributed.")

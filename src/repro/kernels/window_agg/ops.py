"""Jit'd public wrapper for the window_agg kernel (and its oracle)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.window_agg.kernel import ROWS_BLK, window_agg_pallas
from repro.kernels.window_agg.ref import window_agg_ref


def _pad_rows(x, mult):
    r = x.shape[0]
    pad = (-r) % mult
    if pad:
        x = jnp.pad(x, ((0, pad),) + ((0, 0),) * (x.ndim - 1))
    return x, pad


@functools.partial(jax.jit, static_argnames=("k_sigma", "use_pallas",
                                             "interpret"))
def window_agg(values, mask, state_mean, state_var, *, k_sigma: float = 6.0,
               use_pallas: bool = True, interpret: bool = True):
    """Batched entry: values/mask (E, S, T); state (E, S).

    Returns (stats (E, S, N_STATS), spikes (E, S, T)).
    """
    E, S, T = values.shape
    v = values.reshape(E * S, T).astype(jnp.float32)
    m = mask.reshape(E * S, T).astype(jnp.float32)
    mu = state_mean.reshape(E * S, 1).astype(jnp.float32)
    var = state_var.reshape(E * S, 1).astype(jnp.float32)
    if not use_pallas:
        stats, spikes = window_agg_ref(v, m > 0, mu[:, 0], var[:, 0], k_sigma)
    else:
        v, pad = _pad_rows(v, ROWS_BLK)
        m, _ = _pad_rows(m, ROWS_BLK)
        mu, _ = _pad_rows(mu, ROWS_BLK)
        var2, _ = _pad_rows(var, ROWS_BLK)
        stats, spikes = window_agg_pallas(v, m, mu, var2, k_sigma=k_sigma,
                                          interpret=interpret)
        if pad:
            stats, spikes = stats[:E * S], spikes[:E * S]
    return stats.reshape(E, S, -1), spikes.reshape(E, S, T)

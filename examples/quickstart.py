"""Quickstart: Percepta's per-tick pipeline on synthetic heterogeneous
streams — harmonization, anomaly handling, gap filling, normalization,
reward computation — in ~60 lines.

Run: PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core import PerceptaPipeline, PipelineConfig
from repro.core.frame import make_raw_window
from repro.core.reward import RewardSpec, RewardTerm

E, S, M, T = 4, 3, 48, 16          # envs, streams, raw samples, ticks
cfg = PipelineConfig(n_envs=E, n_streams=S, n_ticks=T, tick_s=60.0,
                     max_samples=M, gap_strategy="locf",
                     anomaly_policy="clip")
pipe = PerceptaPipeline(cfg, mode="fused")
state = pipe.init_state()

rng = np.random.RandomState(0)
reward = RewardSpec((
    RewardTerm("linear", weight=-1.0, feature=0),            # cost of stream0
    RewardTerm("band_penalty", weight=2.0, feature=2, target=21.0, band=1.0),
))

for window in range(5):
    t0 = window * T * 60.0
    # three sources at different rates: 30 s / 120 s / 600 s
    rates = [30.0, 120.0, 600.0]
    vals = np.zeros((E, S, M), np.float32)
    ts = np.zeros((E, S, M), np.float32)
    ok = np.zeros((E, S, M), bool)
    for s, r in enumerate(rates):
        n = min(int(T * 60 / r), M)
        ts[:, s, :n] = t0 + (np.arange(n) + 1) * r + rng.uniform(0, 1, (E, n))
        base = [3.0, 0.2, 21.0][s]
        vals[:, s, :n] = base + rng.normal(0, 0.1 * base, (E, n))
        ok[:, s, :n] = rng.rand(E, n) > 0.15          # 15% loss
    vals[0, 0, 3] += 500.0                            # inject a spike
    raw = make_raw_window(vals, ts, ok)

    state, feats, frame = pipe.run_tick(state, raw,
                                        jnp.full((E,), t0, jnp.float32))
    total, per_term = reward.compute(feats.raw,
                                     jnp.zeros((E, 1), jnp.float32))
    print(f"window {window}: observed {float(np.asarray(frame.observed).mean()):.0%} "
          f"filled {float(np.asarray(frame.filled).mean()):.0%} "
          f"spikes {int(np.asarray(frame.anomalous).sum())} "
          f"reward {np.asarray(total).mean():+.2f}")

print("feature vector (env 0):", np.asarray(feats.features)[0].round(2))
print("raw engineering units  :", np.asarray(feats.raw)[0].round(2))

"""Pure-jnp oracle for causal (optionally sliding-window, softcapped) GQA
attention — materializes the full score matrix; ground truth for the kernel."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, window: int = 0, softcap: float = 0.0):
    """q: (B, H, S, D); k, v: (B, Hkv, S, D); H % Hkv == 0. Causal."""
    B, H, S, D = q.shape
    Hkv = k.shape[1]
    G = H // Hkv
    kr = jnp.repeat(k, G, axis=1)
    vr = jnp.repeat(v, G, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q, kr,
                   preferred_element_type=jnp.float32) / math.sqrt(D)
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    qi = jnp.arange(S)[:, None]
    ki = jnp.arange(S)[None, :]
    mask = qi >= ki
    if window:
        mask &= (qi - ki) < window
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p.astype(vr.dtype), vr,
                     preferred_element_type=jnp.float32)
    return out.astype(q.dtype)

# The paper's primary contribution — Percepta's stream-processing tick as
# batched JAX: harmonize -> anomaly -> gap-fill -> normalize -> aggregate ->
# encode -> (model) -> reward -> replay. See pipeline.PerceptaPipeline.
from repro.core.frame import FeatureFrame, RawWindow, TickFrame  # noqa: F401
from repro.core.pipeline import (PerceptaPipeline, PipelineConfig,  # noqa: F401
                                 PipelineState, init_state, tick)

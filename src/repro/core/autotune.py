"""tune_scan_params — short-calibration autotuner for the scan engine.

The scan engine has two free throughput knobs the paper's Manager must pick
per deployment: ``scan_k`` (windows per device dispatch — amortizes Python
dispatch overhead, but grows host staging latency) and the env-mesh split
(how many devices ``distribution.sharding.env_mesh`` spreads the E env rows
over — pays off only once per-device work is large enough). The right cell
depends on the host, the device count, and the (E, S, M, T) shape, so
instead of guessing, ``tune_scan_params`` measures a short calibration grid
of real ``run_many`` dispatches on synthetic windows (deterministic
contents, window-relative timestamps — the device-staging convention) and
returns the windows/s-optimal configuration.

Fused decision path: pass ``decide=`` / ``decide_state=`` (the system does
when ``mode`` is a fused-decide mode) and every grid cell measures the
FUSED engine — ``run_many_decide`` (pipeline tick + policy + reward +
replay in one dispatch), sharded when the cell's mesh split is >1 — so the
tuned (scan_k, mesh) is the argmax of the engine that will actually run.

Two pruning rules keep calibration time off hopeless cells (both
deterministic under a fixed ``measure`` hook — decisions depend only on
measured values and grid order):

  * mesh splits whose per-device env count falls below
    ``min_envs_per_device`` are skipped outright (an E=8 batch spread over
    8 devices is one env row per chip — all dispatch overhead);
  * once any measured cell is more than ``prune_factor`` x slower than the
    incumbent best, the REST of that mesh-split's k column is early-stopped
    (a split that far off at one K has never been observed to close a
    >3x gap within the grid's K range).

Skipped cells are recorded on ``TuneResult.pruned`` so calibration logs
stay auditable. Wired as ``PerceptaSystem(scan_k="auto")``; the ``measure``
hook is injectable so selection logic is deterministic under test (and so
callers can swap in e.g. a median-of-N timer on noisy shared hosts).
"""
from __future__ import annotations

import time
from typing import Callable, NamedTuple, Optional, Sequence


class TuneResult(NamedTuple):
    """Selected configuration + the full measured grid (in measure order)."""
    scan_k: int
    mesh_devices: int
    best_windows_per_s: float
    grid: tuple               # ((scan_k, mesh_devices, windows_per_s), ...)
    pruned: tuple = ()        # ((scan_k|None, mesh_devices, reason), ...)

    def as_dict(self) -> dict:
        return {"scan_k": self.scan_k, "mesh_devices": self.mesh_devices,
                "best_windows_per_s": round(self.best_windows_per_s, 1),
                "grid": [{"scan_k": k, "mesh_devices": n,
                          "windows_per_s": round(w, 1)}
                         for k, n, w in self.grid],
                "pruned": [{"scan_k": k, "mesh_devices": n, "reason": r}
                           for k, n, r in self.pruned]}


def candidate_device_counts(n_envs: int, n_devices: int) -> list:
    """Env-mesh splits worth measuring: device counts dividing E."""
    return [n for n in range(1, n_devices + 1) if n_envs % n == 0]


def _default_measure(fn: Callable[[], None], *, reps: int = 3, **_) -> float:
    """Best-of-reps wall seconds for one dispatch+block (first call warms
    the jit cache and is excluded; min is the robust estimator on shared
    boxes — one preempted rep poisons a mean but not a min)."""
    fn()
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def tune_scan_params(cfg, k_grid: Sequence[int] = (8, 16, 32),
                     device_counts: Optional[Sequence[int]] = None,
                     reps: int = 3, seed: int = 0, valid_p: float = 0.7,
                     measure: Optional[Callable] = None,
                     decide=None, decide_state=None,
                     min_envs_per_device: int = 2,
                     prune_factor: float = 3.0) -> TuneResult:
    """Measure windows/s over ``scan_k`` x env-mesh-split and pick the best.

    ``cfg``: the deployment's :class:`PipelineConfig` (shapes are what make
    the answer deployment-specific). ``device_counts`` defaults to every
    available device count dividing ``cfg.n_envs`` (1 = plain ``scan``;
    >1 = ``scan_sharded`` on an ``env_mesh`` over that many devices).
    ``measure(fn, k=..., n_devices=..., reps=...)`` must return wall seconds
    for one warmed dispatch; the default times real executions. With
    ``decide``/``decide_state`` the cells run the fused decision engine
    instead (``run_many_decide``, donated exactly like production — each
    cell threads fresh copies, so the caller's decide state is untouched).

    Selection is the measured-grid argmax (first in grid order on exact
    ties), so the chosen cell is within measurement noise of the grid
    optimum by construction; determinism under a fixed ``measure`` —
    pruning included — is covered in tests.
    """
    import jax
    import numpy as np

    from repro.core.frame import make_raw_window
    from repro.core.pipeline import (PerceptaPipeline, init_state,
                                     make_run_many_decide_sharded,
                                     run_many_decide)
    from repro.distribution import sharding as shard_lib

    if measure is None:
        measure = _default_measure
    if device_counts is None:
        device_counts = candidate_device_counts(cfg.n_envs,
                                                len(jax.devices()))
    assert (decide is None) == (decide_state is None), \
        "decide and decide_state come as a pair"
    E, S, M = cfg.n_envs, cfg.n_streams, cfg.max_samples
    window_s = cfg.n_ticks * cfg.tick_s
    rng = np.random.RandomState(seed)
    kmax = max(k_grid)
    # one deterministic calibration batch, sliced per K: window-relative
    # timestamps + zero starts, exactly the system's device-staging shape
    values = rng.normal(5, 2, (kmax, E, S, M)).astype(np.float32)
    ts = rng.uniform(0, window_s, (kmax, E, S, M)).astype(np.float32)
    valid = rng.rand(kmax, E, S, M) < valid_p

    grid, pruned = [], []
    best_wps = 0.0
    for ndev in device_counts:
        if ndev > 1 and E // ndev < min_envs_per_device:
            pruned.append((None, int(ndev),
                           f"envs_per_device<{min_envs_per_device}"))
            continue
        if decide is not None:
            import functools

            from repro import compat
            # donate like the production engine: a non-donated cell pays
            # a full replay-ring copy per dispatch (~35 ms at the default
            # capacity) the real fused engine never pays, which would
            # skew the argmax toward large K / wrong mesh splits
            if ndev == 1:
                engine = compat.jit_donated(
                    functools.partial(run_many_decide, cfg, decide),
                    donate_argnums=(0, 1))
            else:
                mesh = shard_lib.env_mesh(E, devices=jax.devices()[:ndev])
                eng, _ = make_run_many_decide_sharded(cfg, decide,
                                                      decide_state, mesh)
                engine = compat.jit_donated(eng, donate_argnums=(0, 1))
        elif ndev == 1:
            pipe = PerceptaPipeline(cfg, mode="scan")
        else:
            mesh = shard_lib.env_mesh(E, devices=jax.devices()[:ndev])
            pipe = PerceptaPipeline(cfg, mode="scan_sharded", mesh=mesh)
        for i, k in enumerate(k_grid):
            raws = make_raw_window(values[:k], ts[:k], valid[:k])
            starts = jax.numpy.zeros((k, E), jax.numpy.float32)
            state = init_state(cfg)

            if decide is not None:
                # donation consumes the carries: thread fresh COPIES of
                # the caller's decide state through a cell-local loop,
                # exactly like the production Manager (the caller's state
                # itself is never donated)
                cell = [state,
                        jax.tree.map(lambda x: jax.numpy.array(x, copy=True),
                                     decide_state)]

                def fn(engine=engine, raws=raws, starts=starts, cell=cell):
                    cell[0], cell[1], outs = engine(cell[0], cell[1], raws,
                                                    starts)
                    jax.block_until_ready(outs.rewards)
            else:
                def fn(pipe=pipe, raws=raws, starts=starts, state=state):
                    _, feats, _ = pipe.run_many(state, raws, starts)
                    jax.block_until_ready(feats.features)

            secs = measure(fn, k=k, n_devices=ndev, reps=reps)
            wps = float(k) / float(secs)
            grid.append((int(k), int(ndev), wps))
            best_wps = max(best_wps, wps)
            if wps * prune_factor < best_wps:
                for k_rest in list(k_grid)[i + 1:]:
                    pruned.append((int(k_rest), int(ndev),
                                   f">{prune_factor:g}x_off_incumbent"))
                break

    if not grid:
        raise ValueError(
            "tune_scan_params: every requested mesh split was pruned "
            f"(device_counts={list(device_counts)}, n_envs={E}, "
            f"min_envs_per_device={min_envs_per_device}; pruned={pruned}). "
            "Include 1 in device_counts or lower min_envs_per_device.")
    best_k, best_n, best = max(grid, key=lambda row: row[2])
    return TuneResult(best_k, best_n, best, tuple(grid), tuple(pruned))

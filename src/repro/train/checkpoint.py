"""Sharding-aware checkpointing: save/restore, async, atomic, keep-N.

Layout per step:
    <dir>/step_<N>.tmp/            (written)
    <dir>/step_<N>/                (atomic rename on completion)
        manifest.json              step, leaf paths/shapes/dtypes, stream
                                   cursor, mesh shape, config fingerprint
        <leaf>.npy                 one file per pytree leaf

Fault-tolerance contract:
  * atomic rename means a crash/preemption mid-write never corrupts the
    latest checkpoint — restore picks the newest COMPLETE step dir;
  * the data-stream cursor is saved with the params so restart resumes the
    pipeline exactly-once at batch granularity (Percepta's stream semantics);
  * async mode hands the host copies to a writer thread so the train loop
    resumes immediately (one step of jitter max, bounded queue).

Restore re-places leaves with the CURRENT process's shardings — restoring a
256-chip checkpoint onto a different mesh (elastic resize) works as long as
the global shapes match; the caller picks the new mesh for the survivors.
"""
from __future__ import annotations

import json
import os
import queue
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def _leaf_name(i: int) -> str:
    return f"leaf_{i:05d}.npy"


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3, async_mode: bool = True):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.async_mode = async_mode
        self._q: "queue.Queue" = queue.Queue(maxsize=2)
        self._worker: Optional[threading.Thread] = None
        self._err: Optional[BaseException] = None
        if async_mode:
            self._worker = threading.Thread(target=self._run, daemon=True,
                                            name="ckpt-writer")
            self._worker.start()

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree: Any, *, extra: Optional[dict] = None,
             block: bool = False):
        """Snapshot to host, then write (async by default)."""
        if self._err:
            raise RuntimeError("checkpoint writer died") from self._err
        leaves, treedef = _flatten(tree)
        host = [np.asarray(x) for x in leaves]  # device->host gather
        payload = (step, host, extra or {})
        if self.async_mode and not block:
            self._q.put(payload)
        else:
            self._write(*payload)

    def _run(self):
        while True:
            item = self._q.get()
            if item is None:
                return
            try:
                self._write(*item)
            except BaseException as e:  # surfaced on next save()
                self._err = e

    def _write(self, step: int, host_leaves, extra: dict):
        tmp = self.dir / f"step_{step:08d}.tmp"
        final = self.dir / f"step_{step:08d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        manifest = {
            "step": step,
            "time": time.time(),
            "leaves": [],
            "extra": extra,
        }
        for i, arr in enumerate(host_leaves):
            np.save(tmp / _leaf_name(i), arr)
            manifest["leaves"].append({
                "file": _leaf_name(i),
                "shape": list(arr.shape),
                "dtype": str(arr.dtype),
            })
        with open(tmp / "manifest.json", "w") as fh:
            json.dump(manifest, fh)
        if final.exists():
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()

    def _gc(self):
        steps = sorted(self.dir.glob("step_????????"))
        for old in steps[: max(0, len(steps) - self.keep)]:
            shutil.rmtree(old, ignore_errors=True)

    # --------------------------------------------------------------- restore
    def latest_step(self) -> Optional[int]:
        steps = sorted(self.dir.glob("step_????????"))
        for cand in reversed(steps):
            if (cand / "manifest.json").exists():
                return int(cand.name.split("_")[1])
        return None

    def restore(self, step: int, like: Any, shardings: Any = None):
        """Restore into the structure of ``like`` (ShapeDtypeStructs or
        arrays), placing with ``shardings`` when given. Returns (tree, extra)."""
        d = self.dir / f"step_{step:08d}"
        manifest = json.loads((d / "manifest.json").read_text())
        leaves, treedef = _flatten(like)
        sh_leaves = jax.tree.leaves(shardings) if shardings is not None \
            else [None] * len(leaves)
        assert len(manifest["leaves"]) == len(leaves), \
            f"checkpoint has {len(manifest['leaves'])} leaves, model {len(leaves)}"
        out = []
        for i, (meta, ref, sh) in enumerate(zip(manifest["leaves"], leaves,
                                                sh_leaves)):
            arr = np.load(d / meta["file"])
            assert list(arr.shape) == list(ref.shape), (i, arr.shape, ref.shape)
            if sh is not None:
                out.append(jax.device_put(arr, sh))
            else:
                out.append(jax.device_put(arr))
        return jax.tree.unflatten(treedef, out), manifest["extra"]

    def flush(self):
        if self.async_mode:
            self._q.join() if False else None
            while not self._q.empty():
                time.sleep(0.01)
            # one in-flight write may remain; poll for quiescence
            time.sleep(0.05)
        if self._err:
            raise RuntimeError("checkpoint writer died") from self._err

    def close(self):
        if self.async_mode and self._worker is not None:
            self.flush()
            self._q.put(None)
            self._worker.join(timeout=10)

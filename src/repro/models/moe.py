"""Mixture-of-Experts FFN with capacity-based sort/scatter dispatch.

TPU adaptation notes:
  * Dispatch is the sort-and-scatter formulation (argsort tokens by expert,
    rank-within-expert, drop beyond capacity, scatter into an (E, C, d)
    buffer) rather than the GShard (S, E, C) one-hot einsum — the one-hot
    dispatch tensor at our shapes (S=4096, E=64, C≈480) is ~250 MB/group and
    dominates HBM traffic; the scatter buffer is E*C*d ≈ tens of MB.
  * Expert weights carry the 'experts' logical dim -> sharded over the mesh
    'model' axis (64/16 = 4 or 16/16 = 1 experts per device). GSPMD turns the
    token->expert resharding into the all-to-all exchange.
  * Compute is proportional to E*C = tokens * top_k * capacity_factor, so
    HLO_FLOPs stay comparable to 6*N_active*D (checked in the roofline's
    MODEL_FLOPS ratio).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro import compat

from repro.models.layers import rms_norm_defs
from repro.models.param import ParamDef


def moe_defs(cfg) -> dict:
    d = cfg.d_model
    m = cfg.moe
    dt = jnp.dtype(cfg.param_dtype)
    s = 0.02
    return {
        "norm": rms_norm_defs(d, dt),
        "router": ParamDef((d, m.n_experts), ("d_model", "experts_router"), dt, "normal", s),
        "w_gate": ParamDef((m.n_experts, d, m.d_ff_expert), ("experts", "d_model", "d_ff"), dt, "normal", s),
        "w_up": ParamDef((m.n_experts, d, m.d_ff_expert), ("experts", "d_model", "d_ff"), dt, "normal", s),
        "w_down": ParamDef((m.n_experts, m.d_ff_expert, d), ("experts", "d_ff", "d_model"), dt, "normal",
                           s / math.sqrt(2 * cfg.n_layers)),
    }


def capacity(n_tokens: int, m) -> int:
    return max(1, int(math.ceil(n_tokens * m.experts_per_token
                                * m.capacity_factor / m.n_experts)))


def moe_apply_sharded(p, x, cfg, mesh, dp_axes):
    """Expert-parallel MoE via shard_map.

    Every (pod, data) rank holds its token shard replicated across the
    'model' axis; every 'model' rank holds E/model_size experts. Each rank
    dispatches its local tokens to its local experts with a purely local
    sort/scatter (no giant one-hot einsum, no global gather — the failure
    mode of letting GSPMD partition the dispatch), computes the expert FFN,
    and the per-token combine is ONE psum over 'model' per layer, the same
    collective cost as a dense TP layer.
    """
    import functools

    from jax.sharding import PartitionSpec as P

    m = cfg.moe
    E, k = m.n_experts, m.experts_per_token
    msize = mesh.shape["model"]
    assert E % msize == 0, (E, msize)
    E_loc = E // msize
    dp = tuple(a for a in dp_axes if a in mesh.axis_names)
    dp_spec = dp if len(dp) > 1 else (dp[0] if dp else None)
    ndp = 1
    for a in dp:
        ndp *= mesh.shape[a]
    B, S, d = x.shape
    T_loc = (B // ndp) * S
    C = capacity(T_loc, m)

    def local_fn(x_loc, router_w, wg, wu, wd):
        Bl, Sl, dl = x_loc.shape
        T = Bl * Sl
        xt = x_loc.reshape(T, dl)
        logits = (xt @ router_w.astype(xt.dtype)).astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        gate_vals, expert_ids = jax.lax.top_k(probs, k)
        gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

        density = jnp.mean(jax.nn.one_hot(expert_ids[:, 0], E, dtype=jnp.float32), axis=0)
        density_proxy = jnp.mean(probs, axis=0)
        aux = jnp.sum(density * density_proxy) * E * m.aux_loss_weight
        if dp:
            aux = jax.lax.pmean(aux, dp)

        offset = jax.lax.axis_index("model") * E_loc
        flat_ids = expert_ids.reshape(-1) - offset            # (T*k,) local ids
        flat_gate = gate_vals.reshape(-1)
        flat_token = jnp.repeat(jnp.arange(T), k)
        in_range = (flat_ids >= 0) & (flat_ids < E_loc)
        key = jnp.where(in_range, flat_ids, E_loc)
        order = jnp.argsort(key, stable=True)
        skey = key[order]
        group_start = jnp.searchsorted(skey, jnp.arange(E_loc), side="left")
        rank = jnp.arange(T * k) - group_start[jnp.clip(skey, 0, E_loc - 1)]
        keep = (skey < E_loc) & (rank < C)
        slot_e = jnp.where(keep, skey, 0)
        slot_c = jnp.where(keep, rank, 0)
        src = flat_token[order]

        contrib = jnp.where(keep[:, None], xt[src], 0).astype(x_loc.dtype)
        buf = jnp.zeros((E_loc, C, dl), x_loc.dtype).at[slot_e, slot_c].add(contrib)

        g = jnp.einsum("ecd,edf->ecf", buf, wg.astype(x_loc.dtype))
        u = jnp.einsum("ecd,edf->ecf", buf, wu.astype(x_loc.dtype))
        h = jax.nn.silu(g.astype(jnp.float32)).astype(x_loc.dtype) * u
        y = jnp.einsum("ecf,efd->ecd", h, wd.astype(x_loc.dtype))

        gathered = y[slot_e, slot_c]
        w8 = jnp.where(keep, flat_gate[order], 0.0)[:, None].astype(x_loc.dtype)
        out = jnp.zeros((T, dl), x_loc.dtype).at[src].add(gathered * w8)
        out = jax.lax.psum(out, "model")
        return out.reshape(Bl, Sl, dl), aux

    fn = compat.shard_map(
        local_fn, mesh=mesh,
        in_specs=(P(dp_spec, None, None), P(None, None),
                  P("model", None, None), P("model", None, None),
                  P("model", None, None)),
        out_specs=(P(dp_spec, None, None), P()),
        check_rep=False)
    return fn(x, p["router"], p["w_gate"], p["w_up"], p["w_down"])


def moe_apply(p, x, cfg, shard=None):
    """x: (B, S, d) -> (out (B, S, d), aux_loss scalar)."""
    if shard is not None:
        mesh, dp_axes = shard
        if mesh.shape.get("model", 1) > 1 and cfg.moe.n_experts % mesh.shape["model"] == 0:
            return moe_apply_sharded(p, x, cfg, mesh, dp_axes)
    m = cfg.moe
    B, S, d = x.shape
    T = B * S
    k = m.experts_per_token
    E = m.n_experts
    C = capacity(T, m)
    xt = x.reshape(T, d)

    logits = (xt @ p["router"].astype(xt.dtype)).astype(jnp.float32)  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, k)                   # (T, k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # Switch-style load-balancing auxiliary loss.
    density = jnp.mean(jax.nn.one_hot(expert_ids[:, 0], E, dtype=jnp.float32), axis=0)
    density_proxy = jnp.mean(probs, axis=0)
    aux = jnp.sum(density * density_proxy) * E * m.aux_loss_weight

    # ---- sort/scatter dispatch --------------------------------------------
    flat_expert = expert_ids.reshape(-1)                 # (T*k,)
    flat_gate = gate_vals.reshape(-1)
    flat_token = jnp.repeat(jnp.arange(T), k)
    order = jnp.argsort(flat_expert, stable=True)        # group by expert
    sorted_expert = flat_expert[order]
    # rank of each assignment within its expert group
    pos = jnp.arange(T * k)
    group_start = jnp.searchsorted(sorted_expert, jnp.arange(E), side="left")
    rank = pos - group_start[sorted_expert]
    keep = rank < C
    slot_e = jnp.where(keep, sorted_expert, 0)
    slot_c = jnp.where(keep, rank, 0)
    src_token = flat_token[order]

    buf = jnp.zeros((E, C, d), x.dtype)
    contrib = jnp.where(keep[:, None], xt[src_token], 0).astype(x.dtype)
    buf = buf.at[slot_e, slot_c].add(contrib)            # (E, C, d)

    # ---- expert FFN (dense over E*C slots; E sharded over 'model') ---------
    g = jnp.einsum("ecd,edf->ecf", buf, p["w_gate"].astype(x.dtype))
    u = jnp.einsum("ecd,edf->ecf", buf, p["w_up"].astype(x.dtype))
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    y = jnp.einsum("ecf,efd->ecd", h, p["w_down"].astype(x.dtype))   # (E, C, d)

    # ---- combine back -------------------------------------------------------
    gathered = y[slot_e, slot_c]                          # (T*k, d)
    weighted = gathered * jnp.where(keep, flat_gate[order], 0.0)[:, None].astype(x.dtype)
    out = jnp.zeros((T, d), x.dtype).at[src_token].add(weighted)
    return out.reshape(B, S, d), aux

"""Accumulator — provider-agnostic per-environment collection.

"Each environment has its own dedicated Accumulator instance, which listens
to the corresponding queue. Upon receiving a message, it forwards the data
to the environment-specific Manager." Here the Accumulator also performs the
device-batch assembly: records -> padded (streams, max_samples) arrays with
validity masks for the window that just closed.
"""
from __future__ import annotations

from collections import defaultdict
from typing import Dict, List, Sequence

import numpy as np

from repro.runtime.queues import EnvQueue
from repro.runtime.records import Record


class Accumulator:
    def __init__(self, env_id: str, streams: Sequence[str], max_samples: int):
        self.env_id = env_id
        self.streams = list(streams)
        self.stream_index = {s: i for i, s in enumerate(self.streams)}
        self.max_samples = max_samples
        self._pending: Dict[int, List[Record]] = defaultdict(list)
        self.stats = {"records": 0, "unknown_stream": 0, "overflow": 0}

    def ingest(self, records: Sequence[Record]):
        for r in records:
            idx = self.stream_index.get(r.stream)
            if idx is None:
                self.stats["unknown_stream"] += 1
                continue
            self.stats["records"] += 1
            self._pending[idx].append(r)

    def close_window(self, t_start: float, t_end: float):
        """Build the padded raw-window arrays for [t_start, t_end) and retain
        newer records for later windows."""
        S, M = len(self.streams), self.max_samples
        values = np.zeros((S, M), np.float32)
        ts = np.zeros((S, M), np.float32)
        valid = np.zeros((S, M), bool)
        for s in range(S):
            recs = self._pending.get(s, [])
            take, keep = [], []
            for r in recs:
                (take if r.timestamp < t_end else keep).append(r)
            self._pending[s] = keep
            take.sort(key=lambda r: r.timestamp)
            if len(take) > M:
                self.stats["overflow"] += len(take) - M
                take = take[-M:]
            for j, r in enumerate(take):
                values[s, j] = r.value
                ts[s, j] = r.timestamp
                valid[s, j] = r.timestamp >= t_start
        return values, ts, valid

    def close_windows(self, bounds):
        """Close K consecutive windows into stacked (K, S, M) arrays.

        ``bounds`` is a chronologically ordered sequence of (t_start, t_end)
        pairs; records newer than the last window end stay pending. This is
        the per-env half of the scan-engine batch assembly — stacking K
        single-window closes keeps the exact per-window record routing of
        ``close_window`` (and therefore per-env isolation: this object only
        ever sees its own env's queue drain).
        """
        K, S, M = len(bounds), len(self.streams), self.max_samples
        values = np.zeros((K, S, M), np.float32)
        ts = np.zeros((K, S, M), np.float32)
        valid = np.zeros((K, S, M), bool)
        for k, (t0, t1) in enumerate(bounds):
            values[k], ts[k], valid[k] = self.close_window(t0, t1)
        return values, ts, valid

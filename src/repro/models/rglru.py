"""RG-LRU recurrent block (RecurrentGemma / Griffin).

Block structure (Griffin recurrent block):
    x -> norm -> [branch A: linear -> temporal conv(4) -> RG-LRU]
              -> [branch B: linear -> GeLU]  -> A * B -> out linear

RG-LRU recurrence (per channel):
    r_t = sigmoid(W_a x_t);  i_t = sigmoid(W_x x_t)
    a_t = exp(-c * softplus(Lambda) * r_t)          (data-dependent decay)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Train/prefill uses ``jax.lax.associative_scan`` over time (the linear
recurrence (a, b) ∘ (a', b') = (a a', a' b + b') is associative) — O(log T)
depth instead of O(T); decode is a single fused update. A Pallas kernel
(kernels/rglru_scan) implements the same recurrence VMEM-tiled for TPU.

A single-step per-env variant of this recurrence also drives the
Percepta decision path: ``runtime/policies.py``'s ``policy="rglru"``
builder applies the gate math row-wise per env with the hidden state
riding the fused-scan carry (``DecideState.carry``), statically
certified for the env-sharded engines by ``analysis/certify.py`` —
including through the ``kernels/rglru_scan`` pallas path
(``use_pallas=True``).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.layers import rms_norm_defs
from repro.models.param import ParamDef

_C = 8.0  # Griffin's fixed decay temperature


def rglru_defs(cfg) -> dict:
    d = cfg.d_model
    w = cfg.lru_width or d
    dt = jnp.dtype(cfg.param_dtype)
    s = 0.02
    return {
        "norm": rms_norm_defs(d, dt),
        "w_x": ParamDef((d, w), ("d_model", "lru_width"), dt, "normal", s),
        "w_gate_branch": ParamDef((d, w), ("d_model", "lru_width"), dt, "normal", s),
        "conv_w": ParamDef((cfg.conv_width, w), ("conv", "lru_width"), dt, "normal", s),
        "conv_b": ParamDef((w,), ("lru_width",), dt, "zeros"),
        # RG-LRU gates (block-diagonal in Griffin; dense-per-channel here)
        "w_a": ParamDef((w,), ("lru_width",), dt, "normal", s),
        "b_a": ParamDef((w,), ("lru_width",), dt, "zeros"),
        "w_i": ParamDef((w,), ("lru_width",), dt, "normal", s),
        "b_i": ParamDef((w,), ("lru_width",), dt, "zeros"),
        "lam": ParamDef((w,), ("lru_width",), dt, "custom",
                        custom=lambda k, sh: jax.random.uniform(k, sh, minval=0.9, maxval=0.999)),
        "w_out": ParamDef((w, d), ("lru_width", "d_model"), dt, "normal",
                          s / math.sqrt(2 * cfg.n_layers)),
    }


def _gates(p, u):
    """u: (..., w) conv output. Returns decay a and gated input (f32)."""
    uf = u.astype(jnp.float32)
    r = jax.nn.sigmoid(uf * p["w_a"].astype(jnp.float32) + p["b_a"].astype(jnp.float32))
    i = jax.nn.sigmoid(uf * p["w_i"].astype(jnp.float32) + p["b_i"].astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(p["lam"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (i * uf)
    return a, gated


def _conv_full(p, x, conv_state=None):
    """Causal depthwise temporal conv, width W. x: (B, S, w)."""
    W = p["conv_w"].shape[0]
    if conv_state is None:
        pad = jnp.zeros((x.shape[0], W - 1, x.shape[2]), x.dtype)
    else:
        pad = conv_state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)  # (B, S+W-1, w)
    out = sum(xp[:, i:i + x.shape[1]] * p["conv_w"][i].astype(x.dtype)
              for i in range(W))
    new_state = xp[:, -(W - 1):] if W > 1 else None
    return out + p["conv_b"].astype(x.dtype), new_state


def rglru_scan(a, b, h0=None):
    """h_t = a_t h_{t-1} + b_t over axis 1 via associative scan. a,b: (B,S,w)."""
    if h0 is not None:
        b = b.at[:, 0].add(a[:, 0] * h0.astype(b.dtype))

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    aa, hs = jax.lax.associative_scan(combine, (a, b), axis=1)
    return hs


def rglru_apply(p, x, cfg, conv_state=None, h_state=None, *, return_state=False):
    """Full-sequence (train/prefill) Griffin recurrent block.

    x: (B, S, d) normalized input. Returns (out (B, S, d), (conv_state, h)).
    """
    xb = jnp.einsum("bsd,dw->bsw", x, p["w_x"].astype(x.dtype))
    gate = jnp.einsum("bsd,dw->bsw", x, p["w_gate_branch"].astype(x.dtype))
    u, new_conv = _conv_full(p, xb, conv_state)
    a, b = _gates(p, u)
    hs = rglru_scan(a, b, h_state)                         # (B, S, w) f32
    h_out = hs.astype(x.dtype) * jax.nn.gelu(gate.astype(jnp.float32)).astype(x.dtype)
    out = jnp.einsum("bsw,wd->bsd", h_out, p["w_out"].astype(x.dtype))
    if return_state:
        return out, (new_conv, hs[:, -1])
    return out, None


def rglru_step(p, x, cfg, conv_state, h_state):
    """Single-token decode step. x: (B, 1, d). States: (B, W-1, w), (B, w)."""
    xb = jnp.einsum("bsd,dw->bsw", x, p["w_x"].astype(x.dtype))
    gate = jnp.einsum("bsd,dw->bsw", x, p["w_gate_branch"].astype(x.dtype))
    W = p["conv_w"].shape[0]
    hist = jnp.concatenate([conv_state.astype(x.dtype), xb], axis=1)  # (B, W, w)
    u = jnp.einsum("bwc,wc->bc", hist, p["conv_w"].astype(x.dtype))[:, None, :]
    u = u + p["conv_b"].astype(x.dtype)
    a, b = _gates(p, u)
    h = a[:, 0] * h_state.astype(jnp.float32) + b[:, 0]               # (B, w)
    h_out = h[:, None, :].astype(x.dtype) * jax.nn.gelu(gate.astype(jnp.float32)).astype(x.dtype)
    out = jnp.einsum("bsw,wd->bsd", h_out, p["w_out"].astype(x.dtype))
    return out, (hist[:, 1:].astype(conv_state.dtype), h)

"""Gemma2-2B — local+global alternating attention, logit softcaps.
[arXiv:2408.00118; hf]"""
from repro.configs.base import ATTN_GLOBAL, ATTN_LOCAL, ModelConfig

CONFIG = ModelConfig(
    name="gemma2-2b",
    family="dense",
    n_layers=26,
    d_model=2304,
    n_heads=8,
    n_kv_heads=4,
    head_dim=256,
    d_ff=9216,
    vocab_size=256000,
    # gemma2 alternates sliding-window and full attention 1:1
    layer_pattern=(ATTN_LOCAL, ATTN_GLOBAL),
    local_window=4096,
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    post_norms=True,
    tie_embeddings=True,
    rope_theta=10000.0,
    source="arXiv:2408.00118; hf:google/gemma-2-2b",
)

"""Pallas TPU kernel: LOCF gap filling in one VMEM pass.

The XLA associative_scan materializes O(log T) full-size intermediates in
HBM; the kernel walks T once per (rows, T) tile with the carry in VREGs —
the gap-fill stage becomes a single streaming read+write.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROWS_BLK = 8


def _kernel(values_ref, obs_ref, init_v_ref, init_h_ref, out_ref, has_ref):
    R, T = values_ref.shape
    v = values_ref[...].astype(jnp.float32)
    o = obs_ref[...] > 0
    carry_v = init_v_ref[...].astype(jnp.float32)   # (R, 1)
    carry_h = init_h_ref[...] > 0

    def body(t, carry):
        cv, ch = carry
        vt = v[:, t][:, None]
        ot = o[:, t][:, None]
        cv = jnp.where(ot, vt, cv)
        ch = ch | ot
        out_ref[:, t] = cv[:, 0]
        has_ref[:, t] = ch[:, 0].astype(jnp.float32)
        return cv, ch

    jax.lax.fori_loop(0, T, body, (carry_v, carry_h))


def locf_pallas(values, observed, init_value, init_has, *,
                interpret: bool = True):
    """values/observed: (R, T) f32; init_value/init_has: (R, 1) f32."""
    R, T = values.shape
    assert R % ROWS_BLK == 0
    out, has = pl.pallas_call(
        _kernel,
        grid=(R // ROWS_BLK,),
        in_specs=[
            pl.BlockSpec((ROWS_BLK, T), lambda i: (i, 0)),
            pl.BlockSpec((ROWS_BLK, T), lambda i: (i, 0)),
            pl.BlockSpec((ROWS_BLK, 1), lambda i: (i, 0)),
            pl.BlockSpec((ROWS_BLK, 1), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((ROWS_BLK, T), lambda i: (i, 0)),
            pl.BlockSpec((ROWS_BLK, T), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((R, T), jnp.float32),
            jax.ShapeDtypeStruct((R, T), jnp.float32),
        ],
        interpret=interpret,
    )(values, observed, init_value, init_has)
    return out, has > 0

"""Phi-3.5-MoE (42B total / 6.6B active) — 16 experts top-2.
[hf:microsoft/Phi-3.5-MoE-instruct]"""
from repro.configs.base import ATTN_GLOBAL, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="phi3.5-moe-42b-a6.6b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=6400,             # per-expert FFN width
    vocab_size=32064,
    layer_pattern=(ATTN_GLOBAL,),
    moe=MoEConfig(n_experts=16, experts_per_token=2, d_ff_expert=6400),
    rope_theta=10000.0,
    source="hf:microsoft/Phi-3.5-MoE-instruct",
)

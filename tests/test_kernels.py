"""Per-Pallas-kernel validation: shape/dtype sweeps vs the pure-jnp oracles
(interpret=True executes the kernel bodies on CPU)."""
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.harmonize.ops import harmonize
from repro.kernels.rglru_scan.ops import rglru_scan
from repro.kernels.window_agg.ops import window_agg


# ---------------------------------------------------------------- window_agg
@pytest.mark.parametrize("E,S,T", [(1, 1, 8), (2, 5, 24), (4, 8, 128),
                                   (3, 3, 17)])
@pytest.mark.parametrize("dtype", [np.float32, np.float64])
def test_window_agg_shapes(E, S, T, dtype, rng):
    v = rng.normal(5, 2, (E, S, T)).astype(dtype)
    m = rng.rand(E, S, T) > 0.3
    mu = rng.normal(5, 1, (E, S)).astype(dtype)
    var = np.abs(rng.normal(2, 0.5, (E, S))).astype(dtype) + 0.1
    s1, sp1 = window_agg(v, m, mu, var, use_pallas=True)
    s2, sp2 = window_agg(v, m, mu, var, use_pallas=False)
    assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-4, atol=1e-4)
    assert (np.asarray(sp1) == np.asarray(sp2)).all()


@given(st.integers(0, 2**16), st.integers(1, 4), st.integers(1, 6),
       st.integers(2, 40))
@settings(max_examples=15, deadline=None)
def test_window_agg_property(seed, E, S, T):
    rng = np.random.RandomState(seed)
    v = rng.normal(0, 10, (E, S, T)).astype(np.float32)
    m = rng.rand(E, S, T) > rng.uniform(0, 0.9)
    mu = rng.normal(0, 1, (E, S)).astype(np.float32)
    var = np.abs(rng.normal(1, 0.3, (E, S))).astype(np.float32) + 0.05
    s1, sp1 = window_agg(v, m, mu, var, use_pallas=True)
    s2, sp2 = window_agg(v, m, mu, var, use_pallas=False)
    assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-4, atol=1e-4)
    assert (np.asarray(sp1) == np.asarray(sp2)).all()


# ----------------------------------------------------------------- harmonize
@pytest.mark.parametrize("E,S,M,T", [(1, 1, 4, 8), (2, 4, 32, 16),
                                     (3, 2, 64, 32), (1, 7, 9, 5)])
def test_harmonize_shapes(E, S, M, T, rng):
    ts = rng.uniform(0, T * 60, (E, S, M)).astype(np.float32)
    vals = rng.normal(0, 1, (E, S, M)).astype(np.float32)
    valid = rng.rand(E, S, M) > 0.2
    ws = np.zeros((E,), np.float32)
    o1, ob1 = harmonize(vals, ts, valid, ws, tick_s=60.0, n_ticks=T,
                        use_pallas=True)
    o2, ob2 = harmonize(vals, ts, valid, ws, tick_s=60.0, n_ticks=T,
                        use_pallas=False)
    assert_allclose(np.asarray(o1), np.asarray(o2), rtol=1e-4, atol=1e-5)
    assert (np.asarray(ob1) == np.asarray(ob2)).all()


@given(st.integers(0, 2**16))
@settings(max_examples=15, deadline=None)
def test_harmonize_property(seed):
    rng = np.random.RandomState(seed)
    E, S = rng.randint(1, 4), rng.randint(1, 5)
    M, T = rng.randint(1, 48), rng.randint(1, 24)
    ts = rng.uniform(-100, (T + 2) * 30, (E, S, M)).astype(np.float32)
    vals = rng.normal(0, 5, (E, S, M)).astype(np.float32)
    valid = rng.rand(E, S, M) > 0.5
    ws = rng.uniform(-50, 50, (E,)).astype(np.float32)
    o1, ob1 = harmonize(vals, ts, valid, ws, tick_s=30.0, n_ticks=T,
                        use_pallas=True)
    o2, ob2 = harmonize(vals, ts, valid, ws, tick_s=30.0, n_ticks=T,
                        use_pallas=False)
    assert_allclose(np.asarray(o1), np.asarray(o2), rtol=1e-4, atol=1e-5)
    assert (np.asarray(ob1) == np.asarray(ob2)).all()


# ---------------------------------------------------------------- rglru_scan
@pytest.mark.parametrize("B,T,W", [(1, 4, 16), (2, 12, 200), (3, 33, 128),
                                   (1, 64, 384)])
def test_rglru_scan_shapes(B, T, W, rng):
    a = rng.uniform(0.5, 0.999, (B, T, W)).astype(np.float32)
    b = rng.normal(0, 0.2, (B, T, W)).astype(np.float32)
    h0 = rng.normal(0, 1, (B, W)).astype(np.float32)
    o1, h1 = rglru_scan(a, b, h0, use_pallas=True)
    o2, h2 = rglru_scan(a, b, h0, use_pallas=False)
    assert_allclose(np.asarray(o1), np.asarray(o2), rtol=1e-5, atol=1e-5)
    assert_allclose(np.asarray(h1), np.asarray(h2), rtol=1e-5, atol=1e-5)


def test_rglru_scan_matches_model_impl(rng):
    """Kernel result == the model's associative_scan implementation."""
    from repro.models.rglru import rglru_scan as assoc_scan
    B, T, W = 2, 16, 128
    a = rng.uniform(0.6, 0.99, (B, T, W)).astype(np.float32)
    b = rng.normal(0, 0.1, (B, T, W)).astype(np.float32)
    h0 = np.zeros((B, W), np.float32)
    o1, _ = rglru_scan(a, b, h0, use_pallas=True)
    o2 = assoc_scan(jnp.asarray(a), jnp.asarray(b))
    assert_allclose(np.asarray(o1), np.asarray(o2), rtol=1e-4, atol=1e-5)


# ----------------------------------------------------------- flash_attention
@pytest.mark.parametrize("B,S,H,Hkv,D", [
    (1, 128, 2, 1, 32),    # MQA
    (2, 256, 4, 2, 32),    # GQA
    (1, 128, 4, 4, 64),    # MHA
])
@pytest.mark.parametrize("window,softcap", [(0, 0.0), (64, 0.0), (0, 50.0)])
def test_flash_attention_sweep(B, S, H, Hkv, D, window, softcap, rng):
    q = rng.normal(0, 1, (B, S, H, D)).astype(np.float32)
    k = rng.normal(0, 1, (B, S, Hkv, D)).astype(np.float32)
    v = rng.normal(0, 1, (B, S, Hkv, D)).astype(np.float32)
    o1 = flash_attention(q, k, v, window=window, softcap=softcap,
                         use_pallas=True, q_blk=64, kv_blk=64)
    o2 = flash_attention(q, k, v, window=window, softcap=softcap,
                         use_pallas=False)
    assert_allclose(np.asarray(o1), np.asarray(o2), rtol=2e-3, atol=2e-3)


def test_flash_attention_bf16(rng):
    B, S, H, Hkv, D = 1, 128, 2, 1, 32
    q = jnp.asarray(rng.normal(0, 1, (B, S, H, D)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(0, 1, (B, S, Hkv, D)), jnp.bfloat16)
    v = jnp.asarray(rng.normal(0, 1, (B, S, Hkv, D)), jnp.bfloat16)
    o1 = flash_attention(q, k, v, use_pallas=True, q_blk=64, kv_blk=64)
    o2 = flash_attention(q, k, v, use_pallas=False)
    assert_allclose(np.asarray(o1, dtype=np.float32),
                    np.asarray(o2, dtype=np.float32), rtol=5e-2, atol=5e-2)


def test_flash_attention_matches_model_blockwise(rng):
    """Kernel == the model's jnp blockwise attention (same recurrence)."""
    from repro.models.layers import blockwise_attention
    B, S, Hkv, G, D = 1, 128, 2, 2, 16
    q = rng.normal(0, 1, (B, S, Hkv, G, D)).astype(np.float32)
    k = rng.normal(0, 1, (B, S, Hkv, D)).astype(np.float32)
    v = rng.normal(0, 1, (B, S, Hkv, D)).astype(np.float32)
    pos = np.broadcast_to(np.arange(S), (B, S)).astype(np.int32)
    out_model = blockwise_attention(
        jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
        q_positions=jnp.asarray(pos), kv_positions=jnp.asarray(pos),
        kv_valid=jnp.ones((B, S), bool), q_chunk=32, kv_chunk=32)
    # kernel layout: q (B, S, H, D) with H = Hkv*G in (kv, g) order
    qk = q.reshape(B, S, Hkv * G, D)
    out_kernel = flash_attention(qk, k, v, use_pallas=True, q_blk=32,
                                 kv_blk=32)
    assert_allclose(np.asarray(out_model).reshape(B, S, -1),
                    np.asarray(out_kernel).reshape(B, S, -1),
                    rtol=2e-3, atol=2e-3)


# ---------------------------------------------------------------------- locf
@pytest.mark.parametrize("E,S,T", [(1, 1, 8), (2, 5, 24), (3, 3, 17)])
def test_locf_kernel_shapes(E, S, T, rng):
    from repro.kernels.locf.ops import locf
    v = rng.normal(0, 1, (E, S, T)).astype(np.float32)
    o = rng.rand(E, S, T) > 0.5
    iv = rng.normal(0, 1, (E, S)).astype(np.float32)
    ih = rng.rand(E, S) > 0.5
    o1, h1 = locf(v, o, iv, ih, use_pallas=True)
    o2, h2 = locf(v, o, iv, ih, use_pallas=False)
    assert_allclose(np.asarray(o1)[np.asarray(h1)],
                    np.asarray(o2)[np.asarray(h2)], rtol=1e-6)
    assert (np.asarray(h1) == np.asarray(h2)).all()


def test_locf_kernel_matches_gapfill_module(rng):
    """Kernel == the core gap-fill LOCF (the stage it accelerates)."""
    from repro.core import gapfill as gf
    from repro.kernels.locf.ops import locf
    import jax.numpy as jnp
    E, S, T = 2, 3, 16
    v = rng.normal(0, 1, (E, S, T)).astype(np.float32)
    o = rng.rand(E, S, T) > 0.5
    state = gf.init_state(E, S)
    want_v, want_h = gf.locf(jnp.asarray(v), jnp.asarray(o), state)
    got_v, got_h = locf(v, o, np.zeros((E, S), np.float32),
                        np.zeros((E, S), bool), use_pallas=True)
    assert (np.asarray(got_h) == np.asarray(want_h)).all()
    assert_allclose(np.asarray(got_v)[np.asarray(got_h)],
                    np.asarray(want_v)[np.asarray(want_h)], rtol=1e-6)

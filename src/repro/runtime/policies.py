"""Certified policy registry — REAL decision models for the fused/sharded
decision path, keyed by name.

``PerceptaSystem(..., policy="rglru")`` (or ``Predictor(model="rglru",
...)``) resolves here: :func:`build_policy` looks the name up in
:data:`POLICIES`, statically certifies the builder against the full
:mod:`repro.analysis` rule catalog (:func:`repro.analysis.certify_policy` —
env row-wise math, shard-size-invariant dot phrasing, recurrent-carry
row stability, pallas BlockSpec env routing, param replication) and only
then builds the :class:`~repro.runtime.predictor.ModelAdapter`, attaching
the :class:`~repro.analysis.certify.PolicyCertificate` the fused/sharded
system modes demand at construction. Certification is cached by
``(name, kwargs, probe shapes)``, so repeated standups of the same policy
skip re-tracing entirely.

Every registered model obeys the bit-identity contract of the env-sharded
fused engine (see ``linear_policy``): per-env row-wise math only, with
every dot phrased as multiply+reduce over the contracted dim
(:func:`_rowdot`) so rounding is independent of rows-per-device. The
recurrent models keep their state in per-env ``(E, ...)`` carry leaves
(``DecideState.carry``) — row i's state stays in row i, the
``carry-env-mix`` invariant — and are single-step re-phrasings of the
sequence models in :mod:`repro.models` (``models/rglru.py``,
``models/rwkv6.py``): same gate math, T=1, env rows as the batch.

Registry idiom: a frozen :class:`PolicyConfig` (name + kwargs) dispatching
through a dict of builders, ``KeyError`` on unknown names.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Mapping

import jax
import jax.numpy as jnp

from repro.analysis.certify import certify_policy
from repro.runtime.predictor import ModelAdapter, linear_policy


def _rowdot(x, w):
    """Per-row dot contracted by multiply+reduce: ``x (..., F) @ w (F, H)``
    without ``dot_general``.

    The add order depends only on the contracted dim, never on the row
    count, so the same bits come out at every shard size — XLA:CPU's gemm
    kernels are row-count-dependent (1-ulp drift per shard size), which is
    why the env-gemm-rows rule bans ``@`` on env rows outright.
    """
    return (x[..., :, None] * w[None]).sum(-2)


def _scale(logits, low, high):
    return jnp.tanh(logits) * (high - low) / 2 + (high + low) / 2


# --------------------------------------------------------------------------
# builders — builder(n_features, n_actions, n_envs=E, **kwargs) -> adapter
# --------------------------------------------------------------------------

def linear_builder(n_features: int, n_actions: int, n_envs: int = None,
                   seed: int = 0, low=-1.0, high=1.0) -> ModelAdapter:
    """The deployed linear policy (``runtime.predictor.linear_policy``)."""
    del n_envs  # stateless and env-count independent
    return linear_policy(n_features, n_actions, seed=seed, low=low, high=high)


def mlp_builder(n_features: int, n_actions: int, n_envs: int = None,
                hidden: int = 32, seed: int = 0,
                low=-1.0, high=1.0) -> ModelAdapter:
    """Two-layer gated MLP (SwiGLU), stateless and row-wise."""
    del n_envs
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    params = {
        "w1": jax.random.normal(k1, (n_features, hidden))
        / jnp.sqrt(n_features),
        "w3": jax.random.normal(k2, (n_features, hidden))
        / jnp.sqrt(n_features),
        "w2": jax.random.normal(k3, (hidden, n_actions)) / jnp.sqrt(hidden),
    }

    def apply(params, feats):
        h = _rowdot(feats, params["w1"])
        g = _rowdot(feats, params["w3"])
        return _scale(_rowdot(jax.nn.silu(g) * h, params["w2"]), low, high)

    fn = jax.jit(lambda feats: apply(params, feats))
    return ModelAdapter(fn, "mlp_policy", params=params, apply=apply)


def rglru_builder(n_features: int, n_actions: int, n_envs: int = None,
                  hidden: int = 16, seed: int = 0, low=-1.0, high=1.0,
                  use_pallas: bool = False) -> ModelAdapter:
    """Recurrent RG-LRU policy — the single-step, env-rows-as-batch
    re-phrasing of ``models/rglru.py``'s gate math, with the recurrence
    update running through the ``kernels/rglru_scan`` op at T=1 (the
    ``lax.scan`` reference by default; ``use_pallas=True`` routes the
    Pallas kernel, whose BlockSpec env routing the certifier checks
    instead of conservatively poisoning).

    Carry: ``{"h": (E, hidden)}`` — per-env hidden state on dim 0.
    """
    from repro.kernels.rglru_scan import ops

    del n_envs  # carry is built by init_carry at the system's env count
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    params = {
        "w_in": jax.random.normal(ks[0], (n_features, hidden))
        / jnp.sqrt(n_features),
        "w_a": jax.random.normal(ks[1], (hidden,)) * 0.1,
        "b_a": jnp.zeros((hidden,)),
        "w_i": jax.random.normal(ks[2], (hidden,)) * 0.1,
        "b_i": jnp.zeros((hidden,)),
        # softplus(lam) in (0, 1)-ish: forget rates spread across the units
        "lam": jnp.linspace(-2.0, 1.0, hidden),
        "w_out": jax.random.normal(ks[3], (hidden, n_actions))
        / jnp.sqrt(hidden),
    }

    def apply_carry(params, feats, carry):
        h = carry["h"]                                   # (E, H)
        u = _rowdot(feats, params["w_in"])               # (E, H)
        r = jax.nn.sigmoid(u * params["w_a"][None] + params["b_a"][None])
        i = jax.nn.sigmoid(u * params["w_i"][None] + params["b_i"][None])
        log_a = -8.0 * jax.nn.softplus(params["lam"])[None] * r
        gated = i * u
        b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * gated
        # one step of h' = a*h + b through the shared scan op (B=E, T=1)
        _, h_new = ops.rglru_scan(jnp.exp(log_a)[:, None, :],
                                  b[:, None, :], h, use_pallas=use_pallas)
        actions = _scale(_rowdot(h_new, params["w_out"]), low, high)
        return actions, {"h": h_new}

    def init_carry(n_envs):
        return {"h": jnp.zeros((n_envs, hidden), jnp.float32)}

    return ModelAdapter(None, "rglru_policy", params=params,
                        apply_carry=apply_carry, init_carry=init_carry)


def rwkv6_builder(n_features: int, n_actions: int, n_envs: int = None,
                  hidden: int = 8, seed: int = 0,
                  low=-1.0, high=1.0) -> ModelAdapter:
    """Recurrent RWKV-6 policy — the single-head, single-step re-phrasing
    of ``models/rwkv6.py``'s ``time_mix_step`` (token shift + data-dependent
    decay + wkv state), env rows as the batch and the attention einsum
    re-phrased as multiply+reduce for shard-size-invariant bits.

    Carry: ``{"shift": (E, F), "wkv": (E, hidden, hidden)}``.
    """
    del n_envs
    ks = jax.random.split(jax.random.PRNGKey(seed), 6)
    D = hidden
    params = {
        "mu": jax.random.uniform(ks[0], (4, n_features)),   # r/k/v/w mixes
        "w_r": jax.random.normal(ks[1], (n_features, D))
        / jnp.sqrt(n_features),
        "w_k": jax.random.normal(ks[2], (n_features, D))
        / jnp.sqrt(n_features),
        "w_v": jax.random.normal(ks[3], (n_features, D))
        / jnp.sqrt(n_features),
        "w_decay": jax.random.normal(ks[4], (n_features, D))
        / jnp.sqrt(n_features),
        "decay_base": jnp.zeros((D,)),
        "bonus": jnp.zeros((D,)),
        "w_o": jax.random.normal(ks[5], (D, n_actions)) / jnp.sqrt(D),
    }

    def apply_carry(params, feats, carry):
        shift, S = carry["shift"], carry["wkv"]          # (E,F), (E,D,D)
        mixed = feats[None] + params["mu"][:, None, :] * (shift - feats)[None]
        r = _rowdot(mixed[0], params["w_r"])             # (E, D)
        k = _rowdot(mixed[1], params["w_k"])
        v = _rowdot(mixed[2], params["w_v"])
        lw = _rowdot(mixed[3], params["w_decay"]) + params["decay_base"][None]
        log_w = jnp.clip(-jnp.exp(jnp.clip(lw, -8.0, 3.0)), -20.0, -1e-5)
        kv = k[..., :, None] * v[..., None, :]           # (E, D, D)
        att = S + params["bonus"][None, :, None] * kv
        out = (r[..., :, None] * att).sum(-2)            # einsum('ek,ekv->ev')
        S_new = jnp.exp(log_w)[..., :, None] * S + kv
        actions = _scale(_rowdot(out, params["w_o"]), low, high)
        return actions, {"shift": feats, "wkv": S_new}

    def init_carry(n_envs):
        return {"shift": jnp.zeros((n_envs, n_features), jnp.float32),
                "wkv": jnp.zeros((n_envs, D, D), jnp.float32)}

    return ModelAdapter(None, "rwkv6_policy", params=params,
                        apply_carry=apply_carry, init_carry=init_carry)


POLICIES = {
    "linear": linear_builder,
    "mlp": mlp_builder,
    "rglru": rglru_builder,
    "rwkv6": rwkv6_builder,
}


@dataclasses.dataclass(frozen=True)
class PolicyConfig:
    """Registry spec: a policy name plus builder kwargs.

    ``PolicyConfig("rglru", {"hidden": 32, "use_pallas": True})`` resolves
    through :func:`build_policy`; unknown names raise ``KeyError`` naming
    the registered set.
    """
    name: str
    kwargs: Mapping[str, Any] = dataclasses.field(default_factory=dict)


def build_policy(spec, n_features: int, n_actions: int, n_envs: int, *,
                 certify: bool = True, **overrides) -> ModelAdapter:
    """Resolve a registry name / :class:`PolicyConfig` to a certified
    :class:`~repro.runtime.predictor.ModelAdapter`.

    Certification runs BEFORE the adapter is built for the system's real
    shapes, at small-E probes with the real feature/action counts (plus
    the two-env-count param-replication probe), and raises
    :class:`~repro.analysis.contracts.ContractViolation` naming rule,
    primitive and source on a bad builder. The resulting certificate is
    attached as ``adapter.certificate`` — the fused/sharded system modes
    demand it at construction — and cached by
    ``(name, kwargs, probe shapes)`` so repeated standups skip re-tracing.
    """
    if isinstance(spec, str):
        spec = PolicyConfig(spec)
    try:
        builder = POLICIES[spec.name]
    except KeyError:
        raise KeyError(
            f"Unrecognized policy provided: {spec.name!r} "
            f"(registered: {sorted(POLICIES)})") from None
    kwargs = dict(spec.kwargs)
    kwargs.update(overrides)
    bound = functools.partial(builder, **kwargs) if kwargs else builder
    cert = None
    if certify:
        probes = ((4, n_features, n_actions),)
        key = (spec.name, tuple(sorted(kwargs.items())), probes)
        cert = certify_policy(bound, probes, name=spec.name, cache_key=key)
    adapter = bound(n_features, n_actions, n_envs=n_envs)
    adapter.certificate = cert
    return adapter

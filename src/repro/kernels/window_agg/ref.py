"""Pure-jnp oracle for the fused window-stats + anomaly-mask kernel."""
from __future__ import annotations

import jax.numpy as jnp

N_STATS = 8  # mean, var, min, max, last, count, sum, anomaly_count


def window_agg_ref(values, mask, state_mean, state_var, k_sigma: float):
    """values/mask: (R, T) f32/bool rows; state_mean/var: (R,).

    Returns (stats (R, N_STATS) f32, spikes (R, T) bool) where stats columns
    are [mean, var, min, max, last, count, sum, n_spikes] over masked ticks.
    Spikes are z-score outliers against the carried running stats.
    """
    values = values.astype(jnp.float32)
    w = mask.astype(jnp.float32)
    n = w.sum(-1)
    s = (values * w).sum(-1)
    mean = s / jnp.maximum(n, 1.0)
    var = (jnp.square(values - mean[:, None]) * w).sum(-1) / jnp.maximum(n, 1.0)
    big = jnp.float32(3.4e38)
    vmin = jnp.min(jnp.where(mask, values, big), -1)
    vmax = jnp.max(jnp.where(mask, values, -big), -1)
    T = values.shape[-1]
    idx = jnp.where(mask, jnp.arange(T), -1).max(-1)
    last = jnp.take_along_axis(values, jnp.maximum(idx, 0)[:, None], -1)[:, 0]
    last = jnp.where(idx >= 0, last, 0.0)
    vmin = jnp.where(n > 0, vmin, 0.0)
    vmax = jnp.where(n > 0, vmax, 0.0)

    sigma = jnp.sqrt(jnp.maximum(state_var, 1e-12))
    z = jnp.abs(values - state_mean[:, None]) / sigma[:, None]
    spikes = mask & (z > k_sigma)
    stats = jnp.stack([mean, var, vmin, vmax, last, n, s,
                       spikes.sum(-1).astype(jnp.float32)], axis=-1)
    return stats, spikes

"""Registry of all selectable architectures (``--arch <id>``)."""
from __future__ import annotations

from repro.configs import (
    deepseek_coder_33b,
    gemma2_2b,
    internlm2_20b,
    internvl2_26b,
    moonshot_v1_16b_a3b,
    musicgen_medium,
    phi3_5_moe_42b,
    qwen3_0_6b,
    recurrentgemma_2b,
    rwkv6_1_6b,
)
from repro.configs.base import ModelConfig, reduced

_MODULES = {
    "internlm2-20b": internlm2_20b,
    "gemma2-2b": gemma2_2b,
    "qwen3-0.6b": qwen3_0_6b,
    "deepseek-coder-33b": deepseek_coder_33b,
    "recurrentgemma-2b": recurrentgemma_2b,
    "musicgen-medium": musicgen_medium,
    "moonshot-v1-16b-a3b": moonshot_v1_16b_a3b,
    "phi3.5-moe-42b-a6.6b": phi3_5_moe_42b,
    "rwkv6-1.6b": rwkv6_1_6b,
    "internvl2-26b": internvl2_26b,
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch: str) -> ModelConfig:
    if arch.endswith(":smoke"):
        return reduced(get_config(arch[: -len(":smoke")]))
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_MODULES)}")
    return _MODULES[arch].CONFIG


def all_configs() -> dict:
    return {a: get_config(a) for a in ARCH_IDS}
